"""EcoreService + policy layer: request-centric serving over RoutingPolicy.

Covers PoolPolicy decide/decide_batch parity, the single Observation plane,
inline full-batch flushes, drain/close semantics, and the threaded
deadline-bounded flusher (fake clock, event ordering, ZERO poll() calls,
bit-for-bit parity with solo serving)."""
import time

import numpy as np
import pytest

from repro.core.policy import Observation, PoolPolicy, RouteRequest
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.engine import Backend, DispatchQueue, Request, Result
from repro.serving.pool import LENGTH_BUCKETS, ServingPool
from repro.serving.service import EcoreService


def _pool(delta=5.0):
    # 'small' degrades with the bucket, 'big' holds: routing varies by length
    entries = [ProfileEntry(a, "pod", b, score - drop * b, 1.0, energy)
               for a, score, drop, energy in (("small", 80.0, 3.0, 1.0),
                                              ("big", 84.0, 1.0, 5.0))
               for _, _, b in LENGTH_BUCKETS]
    return ServingPool(ProfileTable(entries), delta=delta)


class _StubBackend:
    def __init__(self, name="stub", max_batch=4):
        self.name = name
        self.max_batch = max_batch
        self.batch_sizes = []

    def serve_batch(self, requests):
        self.batch_sizes.append(len(requests))
        return [Result(uid=r.uid, tokens=np.zeros(1, np.int32),
                       prefill_s=.01, decode_s=.01, backend=self.name,
                       batch_size=len(requests)) for r in requests]


class ManualClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


def _req(uid, plen):
    return RouteRequest(uid=uid, complexity=plen, payload=np.arange(8),
                        max_new_tokens=4)


def _wait_until(pred, timeout_s=5.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout_s:
            raise AssertionError("condition not reached in time")
        time.sleep(0.002)


# ---------------------------------------------------------------- policies

def test_pool_policy_batch_matches_scalar():
    policy = PoolPolicy(_pool())
    assert policy.batchable is True
    reqs = [_req(i, plen) for i, plen in enumerate(
        [1, 100, 513, 2049, 8193, 32769, 600_000])]
    batch = policy.decide_batch(reqs)
    scalar = [policy.decide(r) for r in reqs]
    assert batch == scalar
    assert {d.backend for d in batch} == {"small", "big"}  # routing varied
    d = batch[0]
    assert d.pair == ("small", "pod") and d.group == 0
    assert d.energy_mwh == 1.0 and d.score == 80.0


def test_pool_policy_empty_batch():
    assert PoolPolicy(_pool()).decide_batch([]) == []


# ------------------------------------------------------- service, untimed

def test_service_full_batch_flushes_inline():
    built = []

    def factory(decision):
        be = _StubBackend(decision.backend, max_batch=2)
        built.append(be)
        return be

    service = EcoreService(PoolPolicy(_pool()), factory)
    assert service._flusher is None       # no deadline -> no thread
    futs = [service.submit(_req(i, 64)) for i in range(3)]
    assert futs[0].done() and futs[1].done()   # batch of 2 went out inline
    assert not futs[2].done()
    assert [s.result.uid for s in service.results()] == [0, 1]
    drained = service.drain()
    assert [s.result.uid for s in drained] == [2] and futs[2].done()
    assert len(built) == 1 and built[0].batch_sizes == [2, 1]
    service.close()
    with pytest.raises(RuntimeError):
        service.submit(_req(9, 64))


def test_service_submit_batch_routes_in_one_call(monkeypatch):
    scalar_decides = []
    orig = PoolPolicy.decide
    monkeypatch.setattr(PoolPolicy, "decide",
                        lambda self, r: scalar_decides.append(r.uid)
                        or orig(self, r))
    service = EcoreService(PoolPolicy(_pool()),
                           lambda d: _StubBackend(d.backend, 4))
    futs = service.submit_batch([_req(i, 64) for i in range(4)])
    assert all(f.done() for f in futs)    # one full batch, flushed inline
    assert scalar_decides == []           # tensorized path only
    assert service.stats()["serve_calls"] == 1
    service.close()


def test_service_close_flushes_pending_and_is_idempotent():
    service = EcoreService(PoolPolicy(_pool()),
                           lambda d: _StubBackend(d.backend, 8))
    fut = service.submit(_req(0, 64))
    assert not fut.done()
    service.close()
    service.close()
    assert fut.done()                     # no dangling futures
    assert [s.result.uid for s in service.results()] == [0]


def test_service_observe_plane_closes_the_loop():
    entries = [ProfileEntry(a, "pod", b, 80.0, 1.0, energy)
               for a, energy in (("small", 1.0), ("big", 5.0))
               for _, _, b in LENGTH_BUCKETS]
    pool = ServingPool(ProfileTable(entries), delta=5.0)
    service = EcoreService(PoolPolicy(pool, alpha=0.3),
                           lambda d: _StubBackend(d.backend, 1))
    assert service.submit(_req(0, 100)).result().decision.backend == "small"
    for _ in range(30):  # 'small' measured far more expensive than profiled
        service.observe(Observation(pair=("small", "pod"), energy_mwh=50.0))
    assert service.submit(_req(1, 100)).result().decision.backend == "big"
    service.close()


def test_service_duplicate_inflight_uid_is_rejected():
    service = EcoreService(PoolPolicy(_pool()),
                           lambda d: _StubBackend(d.backend, 8))
    service.submit(_req(0, 64))          # stays pending (batch of 8)
    with pytest.raises(ValueError, match="already in flight"):
        service.submit(_req(0, 64))
    service.close()


class _FailingBackend(_StubBackend):
    def serve_batch(self, requests):
        raise RuntimeError("backend exploded")


def test_service_backend_error_fails_futures_not_the_service():
    """A serve_batch error must surface on the affected futures AND the
    direct caller — and must not dangle other backends' requests."""
    def factory(decision):
        cls = _FailingBackend if decision.backend == "small" else _StubBackend
        return cls(decision.backend, max_batch=2)

    service = EcoreService(PoolPolicy(_pool()), factory)
    f0 = service.submit(_req(0, 64))             # 'small', pending
    with pytest.raises(RuntimeError, match="backend exploded"):
        service.submit(_req(1, 64))              # fills the batch -> serve
    assert isinstance(f0.exception(), RuntimeError)
    # the healthy 'big' backend still serves (long prompt -> 'big')
    f2 = service.submit(_req(2, 600_000))
    drained = service.drain()
    assert [s.result.uid for s in drained] == [2] and f2.done()
    service.close()


def test_detection_policy_observe_needs_group_or_true_complexity():
    """A quality observation with no way to place it must fail loudly (and
    group derivation from the true count must work), matching the pool
    face's per-bucket guard."""
    from repro.core.policy import DetectionPolicy
    from repro.core.router import GreedyEstimateRouter

    table = ProfileTable([ProfileEntry("m", "d", g, 50.0, 1.0, 0.1)
                          for g in range(5)])
    policy = DetectionPolicy(GreedyEstimateRouter(table, 5.0), table,
                             alpha=0.5)
    with pytest.raises(ValueError, match="per-group"):
        policy.observe(Observation(pair=("m", "d"), map_pct=10.0))
    policy.observe(Observation(pair=("m", "d"), map_pct=10.0,
                               true_complexity=7))   # -> group 4
    assert policy.table.entry(("m", "d"), 4).map_pct == 30.0
    assert policy.table.entry(("m", "d"), 0).map_pct == 50.0


def test_pool_policy_observe_derives_bucket_from_true_complexity():
    """Observation contract: group may be omitted when true_complexity is
    given — the pool face derives the bucket itself."""
    entries = [ProfileEntry(a, "pod", b, 80.0, 1.0, energy)
               for a, energy in (("small", 1.0), ("big", 5.0))
               for _, _, b in LENGTH_BUCKETS]
    policy = PoolPolicy(ServingPool(ProfileTable(entries)), alpha=0.5)
    policy.observe(Observation(pair=("small", "pod"), map_pct=0.0,
                               true_complexity=1024))
    assert policy.pool.table.entry(("small", "pod"), 1).map_pct == 40.0
    assert policy.pool.table.entry(("small", "pod"), 0).map_pct == 80.0


# --------------------------------------------------- threaded deadline flush

@pytest.mark.threads
def test_threaded_flusher_serves_deadline_expired_partial_batch(monkeypatch):
    """Event ordering under a fake clock: nothing is served before
    max_wait_ms, the partial batch goes out right after the deadline
    expires, and NOBODY calls cooperative poll()."""
    def no_poll(self):
        raise AssertionError("cooperative poll() must never be called")
    monkeypatch.setattr(DispatchQueue, "poll", no_poll)

    clock = ManualClock()
    be = _StubBackend(max_batch=4)
    service = EcoreService(PoolPolicy(_pool()), lambda d: be,
                           max_wait_ms=50.0, clock=clock)
    futs = [service.submit(_req(i, 64)) for i in range(2)]
    assert not any(f.done() for f in futs)  # 2/4: waiting for the batch

    clock.advance_ms(49.9)
    service.wake()
    passes = service.flusher_passes
    _wait_until(lambda: service.flusher_passes > passes + 1)
    assert not any(f.done() for f in futs)  # deadline not reached yet
    assert service.deadline_flushes == 0

    clock.advance_ms(0.2)                   # oldest waited past 50 ms
    service.wake()
    served = [f.result(timeout=5.0) for f in futs]
    assert [s.result.uid for s in served] == [0, 1]
    assert be.batch_sizes == [2]            # ONE partial flush
    assert service.deadline_flushes == 1
    stats = service.stats()
    assert stats["serve_calls"] == 1 and stats["served"] == 2
    # queue wait is measured on the injected clock
    assert stats["queue_wait_ms"][0] == pytest.approx(50.1, abs=0.2)
    service.close()


@pytest.mark.threads
def test_flusher_thread_survives_backend_errors():
    """A backend blowing up during a deadline flush must fail that batch's
    futures, not kill the flusher — later deadlines still get served."""
    def factory(decision):
        cls = _FailingBackend if decision.backend == "small" else _StubBackend
        return cls(decision.backend, max_batch=4)

    clock = ManualClock()
    service = EcoreService(PoolPolicy(_pool()), factory,
                           max_wait_ms=50.0, clock=clock)
    bad = service.submit(_req(0, 64))            # -> failing 'small'
    good = service.submit(_req(1, 600_000))      # -> healthy 'big'
    clock.advance_ms(51)
    service.wake()
    assert isinstance(bad.exception(timeout=5.0), RuntimeError)
    assert good.result(timeout=5.0).result.uid == 1
    assert service.deadline_flushes == 2
    assert service._flusher.is_alive()       # survived the backend error
    # a results()-driven driver must not lose the batch silently: the
    # swallowed background error resurfaces at drain()
    with pytest.raises(RuntimeError, match="backend exploded"):
        service.drain()
    assert [s.result.uid for s in service.results()] == [1]
    service.close()                          # error consumed: closes clean


@pytest.mark.threads
def test_threaded_flush_results_match_solo_serving(monkeypatch):
    """A deadline-flushed batch must return bit-for-bit the tokens solo
    serving returns (equal-length prompts: one homogeneous serve_batch)."""
    def no_poll(self):
        raise AssertionError("cooperative poll() must never be called")
    monkeypatch.setattr(DispatchQueue, "poll", no_poll)

    from repro.configs import get_config
    cfg = get_config("qwen2.5-3b").reduced()
    be = Backend("qwen", cfg, max_batch=4, max_seq=64)
    clock = ManualClock()
    service = EcoreService(PoolPolicy(_pool()), lambda d: be,
                           max_wait_ms=20.0, clock=clock)
    futs = [service.submit(RouteRequest(uid=i, complexity=64,
                                        payload=np.arange(7) * (i + 1),
                                        max_new_tokens=3))
            for i in range(3)]
    assert not any(f.done() for f in futs)
    clock.advance_ms(21)
    service.wake()
    served = [f.result(timeout=120.0) for f in futs]
    assert service.deadline_flushes == 1
    for s in served:
        assert s.result.batch_size == 3
        solo = be.serve_batch([Request(uid=s.request.uid,
                                       prompt=s.request.payload,
                                       max_new_tokens=3)])[0]
        np.testing.assert_array_equal(s.result.tokens, solo.tokens)
    service.close()


# --------------------------------------- structured close + error planes

def test_service_closed_is_structured_and_terminal():
    from repro.serving.service import ServiceClosed

    service = EcoreService(PoolPolicy(_pool()),
                           lambda d: _StubBackend(d.backend, max_batch=4))
    fut = service.submit(_req(0, 64))
    service.close()                      # flushes: the future resolves
    assert fut.result(5.0).result.uid == 0
    service.close()                      # idempotent
    with pytest.raises(ServiceClosed):
        service.submit(_req(1, 64))
    with pytest.raises(ServiceClosed):
        service.submit_batch([_req(1, 64)])
    with EcoreService(PoolPolicy(_pool()),
                      lambda d: _StubBackend(d.backend)) as ctx:
        pass
    with pytest.raises(ServiceClosed):   # __exit__ closed it
        ctx.submit(_req(2, 64))


@pytest.mark.threads
def test_buffer_errors_toggle_controls_drain_reraise():
    """buffer_errors=True (results()-driven drivers): a flusher-swallowed
    backend error resurfaces at drain().  buffer_errors=False (futures-only
    drivers): the futures already carry it — drain stays silent instead of
    double-reporting."""
    def factory(decision):
        cls = _FailingBackend if decision.backend == "small" else _StubBackend
        return cls(decision.backend, max_batch=4)

    for buffered in (True, False):
        clock = ManualClock()
        service = EcoreService(PoolPolicy(_pool()), factory,
                               max_wait_ms=50.0, clock=clock,
                               buffer_errors=buffered)
        bad = service.submit_batch([_req(0, 64), _req(1, 64)])  # 'small'
        clock.advance_ms(51)
        service.wake()
        for f in bad:                      # futures carry it either way
            assert isinstance(f.exception(timeout=5.0), RuntimeError)
        if buffered:
            with pytest.raises(RuntimeError, match="backend exploded"):
                service.drain()
            service.close()                # error consumed: closes clean
        else:
            assert service.drain() == []   # no re-raise, no double report
            service.close()


# ------------------------------------------- queue-wait / service split

@pytest.mark.threads
def test_queue_wait_excludes_service_time():
    """The two latency planes must not be folded together: queue wait ends
    when the flush TRIGGERS (deadline expiry here), service time covers
    trigger -> completion — slow serving must not inflate 'queue wait'."""
    clock = ManualClock()
    service = EcoreService(PoolPolicy(_pool()),
                           lambda d: _StubBackend(d.backend, max_batch=4),
                           max_wait_ms=50.0, clock=clock)
    service.submit(_req(0, 64))          # partial batch: waits for deadline
    clock.advance_ms(200)                # flusher was slow to get there
    service.wake()
    _wait_until(lambda: service.stats()["served"] == 1)
    stats = service.stats()
    # wait = submit -> deadline EXPIRY (50 ms), not submit -> completion
    assert stats["queue_wait_ms"] == [pytest.approx(50.0)]
    # service = expiry -> completion on the same clock (the remaining 150)
    assert stats["service_ms"] == [pytest.approx(150.0)]
    service.close()


def test_inline_full_batch_flush_has_zero_queue_wait():
    clock = ManualClock()
    service = EcoreService(PoolPolicy(_pool()),
                           lambda d: _StubBackend(d.backend, max_batch=2),
                           clock=clock)
    service.submit(_req(0, 64))
    service.submit(_req(1, 64))          # fills the batch: inline flush
    stats = service.stats()
    assert stats["queue_wait_ms"] == [pytest.approx(0.0)] * 2
    assert stats["service_ms"] == [pytest.approx(0.0)] * 2
    service.close()

"""Serving engine + TPU pool routing tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.engine import Backend, Request
from repro.serving.pool import (LENGTH_BUCKETS, ServingPool, bucket_of,
                                capability_score)


def test_bucket_of():
    assert bucket_of(10) == 0
    assert bucket_of(513) == 1
    assert bucket_of(8193) == 3
    assert bucket_of(600_000) == 4


def test_capability_saturation():
    small = capability_score(3_000_000_000, False, 0)
    big = capability_score(34_000_000_000, False, 0)
    assert big - small < 5.0  # short prompts: capacity saturates
    small4 = capability_score(3_000_000_000, True, 4)
    big4 = capability_score(34_000_000_000, True, 4)
    assert big4 - small4 > 10.0  # long prompts discriminate
    # full-attention pays a long-context quality penalty
    assert capability_score(10**10, True, 4) > capability_score(10**10, False, 4)


def test_pool_routing_prefers_cheap_for_short():
    entries = []
    for arch, score_base, energy in (("small", 70.0, 1.0), ("big", 90.0, 5.0)):
        for _, _, b in LENGTH_BUCKETS:
            cap = {0: 72.0, 1: 78.0, 2: 84.0, 3: 92.0, 4: 99.0}[b]
            entries.append(ProfileEntry(arch, "pod", b,
                                        min(score_base, cap), 1.0, energy))
    pool = ServingPool(ProfileTable(entries), delta=5.0)
    assert pool.route(100).arch == "small"   # bucket 0: both ~70/72 -> cheap
    assert pool.route(40_000).arch == "big"  # bucket 4: 90 vs 70 -> big only


def test_backend_serve_batch():
    cfg = get_config("qwen2.5-3b").reduced()
    be = Backend("qwen", cfg, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(5 + i), max_new_tokens=3)
            for i in range(2)]
    results = be.serve_batch(reqs)
    assert len(results) == 2
    for r in results:
        assert r.tokens.shape == (3,)
        assert r.prefill_s > 0 and r.decode_s >= 0
        assert (r.tokens >= 0).all() and (r.tokens < cfg.vocab_size).all()


def test_backend_stateful_families():
    cfg = get_config("mamba2-370m").reduced()
    be = Backend("mamba", cfg, max_seq=64)
    res = be.serve_batch([Request(uid=0, prompt=np.arange(7),
                                  max_new_tokens=4)])[0]
    assert res.tokens.shape == (4,)

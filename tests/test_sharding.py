"""Sharding spec rules: divisibility, mode differences, batch specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.sharding import specs as sp
from repro.sharding import ctx


def test_spec_rules_basic():
    s = sp.spec_for_param("blocks/s0/attn/wq", (2, 64, 128), mode="train")
    assert s == P(None, "data", "model")
    s = sp.spec_for_param("blocks/s0/attn/wq", (2, 64, 128), mode="serve")
    assert s == P(None, None, "model")
    s = sp.spec_for_param("embed/table", (1000, 64), mode="train")
    assert s == P("model", "data")
    s = sp.spec_for_param("final_norm", (64,), mode="train")
    assert s == P(None)
    s = sp.spec_for_param("blocks/s0/moe/w_gate", (4, 8, 64, 128), mode="train")
    assert s == P(None, None, "data", "model")


def test_divisibility_drops_axes():
    mesh = make_mesh((1, 1), ("data", "model"))
    # fake a 16x16 mesh via explicit shape map
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    s = sp.spec_for_param("embed/table", (50280, 1024), mode="train",
                          mesh=FakeMesh())
    assert s == P(None, "data")  # 50280 % 16 != 0 -> vocab axis dropped
    s = sp.spec_for_param("embed/table", (256000, 2560), mode="train",
                          mesh=FakeMesh())
    assert s == P("model", "data")


def test_batch_spec():
    mesh = make_mesh((1, 1), ("data", "model"))
    assert sp.batch_spec(mesh, 8, 2) == P(("data",), None)
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    assert sp.batch_spec(FakeMesh(), 64, 3) == P(("pod", "data"), None, None)
    assert sp.batch_spec(FakeMesh(), 1, 2) == P(None, None)  # non-divisible


def test_ctx_noop_outside_context():
    x = jnp.ones((4, 8))
    assert ctx.constrain_batch(x) is x
    assert ctx.batch_axes() is None


def test_ctx_skips_non_divisible():
    mesh = make_mesh((1, 1), ("data", "model"))
    with ctx.activation_sharding(("data",), 16, mesh=mesh):
        x = jnp.ones((3, 8))  # 3 % 16 != 0
        assert ctx.constrain_batch(x) is x
        assert ctx.batch_axes() == ("data",)
        assert ctx.current_mesh() is mesh

"""Deliverable (f): per-assigned-architecture smoke tests.

Each instantiates a REDUCED variant of the same family (<=2 blocks,
d_model<=512, <=4 experts) and runs one forward + one train step on CPU,
asserting output shapes and absence of NaNs.  The FULL configs are exercised
only via the dry-run (ShapeDtypeStructs, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.launch.steps import make_train_step
from repro.models import forward, init_params
from repro.optim.adamw import AdamWConfig, init_opt_state

ARCHS = list_configs(include_variants=True)


def _batch_for(cfg, B=2, S=16):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.ones(
            (B, cfg.num_prefix_embeds, cfg.vision_dim))
    if cfg.family == "encdec":
        batch["prefix_embeds"] = jnp.ones((B, cfg.enc_seq, cfg.vision_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits = forward(params, cfg, batch["tokens"],
                     batch.get("prefix_embeds"))
    B, S = batch["tokens"].shape
    S_out = S + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = make_train_step(cfg, AdamWConfig(total_steps=10))
    batch = _batch_for(cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert int(opt2.step) == 1
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, params2)
    assert max(jax.tree.leaves(diffs)) > 0


def test_exact_assigned_specs():
    """The full configs carry the exact assigned hyperparameters."""
    c = get_config("llama3-8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (32, 4096, 32, 8, 14336, 128256)
    c = get_config("gemma2-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (42, 3584, 16, 8, 14336, 256000)
    assert c.attn_softcap and c.final_softcap
    c = get_config("deepseek-v2-lite-16b")
    assert c.use_mla and c.kv_lora_rank == 512 and c.num_experts == 64 \
        and c.moe_top_k == 6 and c.num_shared_experts == 2
    c = get_config("granite-moe-1b-a400m")
    assert c.num_experts == 32 and c.moe_top_k == 8
    c = get_config("mamba2-370m")
    assert c.ssm_state == 128 and c.num_layers == 48 and c.is_subquadratic
    c = get_config("recurrentgemma-2b")
    assert c.num_layers == 26 and c.is_subquadratic
    assert c.block_layout == ("rec", "rec", "local")
    c = get_config("whisper-small")
    assert c.enc_layers == 12 and c.dec_layers == 12 and c.enc_seq == 1500
    c = get_config("qwen2.5-3b")
    assert c.qkv_bias and c.num_kv_heads == 2
    c = get_config("llava-next-34b")
    assert c.num_prefix_embeds == 2880 and c.num_heads == 56
    c = get_config("deepseek-7b")
    assert c.num_kv_heads == 32  # MHA

"""End-to-end behaviour tests: the full ECORE system over a real (small)
testbed — trained detectors, profiling, estimators, routers, gateway.

Uses a session-scoped quick testbed (2 detectors, fewer training steps) so
the suite stays CPU-friendly; the full 8-model testbed is exercised by the
benchmarks.
"""
import numpy as np
import pytest

from repro.core import (EdgeDetectionEstimator, Gateway, GreedyEstimateRouter,
                        HighestMAPPerGroupRouter, LowestEnergyRouter,
                        OracleEstimator, OracleRouter, OutputBasedEstimator,
                        ProfileTable)
from repro.core.estimators import SSDFrontEndEstimator
from repro.detection import scenes as sc
from repro.detection.train import profile_pairs, train_detector
from repro.detection.detectors import DETECTOR_CONFIGS


@pytest.fixture(scope="session")
def testbed():
    params = {
        "ssd_v1": train_detector(DETECTOR_CONFIGS["ssd_v1"], steps=250,
                                 seed=0),
        "yolov8_n": train_detector(DETECTOR_CONFIGS["yolov8_n"], steps=250,
                                   seed=1),
    }
    table = profile_pairs(params,
                          [("ssd_v1", "pi5_tpu"), ("ssd_v1", "orin_nano"),
                           ("yolov8_n", "pi5_aihat")],
                          val_scenes=sc.full_dataset(80, seed=42))
    return params, table


def _run(testbed, router_cls, estimator, scenes, delta=5.0):
    params, table = testbed
    router = router_cls(table, delta)
    gw = Gateway(router, table, params, estimator)
    return gw.process_stream(scenes)


def test_profile_table_structure(testbed):
    _, table = testbed
    assert len(table.pairs()) == 3
    assert {e.group for e in table.entries} == {0, 1, 2, 3, 4}
    assert all(e.energy_mwh > 0 and e.time_ms > 0 for e in table.entries)


def test_hmg_upper_bounds_accuracy(testbed):
    scenes = sc.full_dataset(40, seed=11)
    hmg = _run(testbed, HighestMAPPerGroupRouter, None, scenes)
    le = _run(testbed, LowestEnergyRouter, None, scenes)
    assert hmg.map_pct >= le.map_pct - 2.0  # HMG at/above LE (eval noise tol)
    assert le.backend_energy_mwh <= hmg.backend_energy_mwh + 1e-9


def test_oracle_between_le_and_hmg(testbed):
    scenes = sc.full_dataset(40, seed=12)
    hmg = _run(testbed, HighestMAPPerGroupRouter, None, scenes)
    orc = _run(testbed, OracleRouter, OracleEstimator(), scenes)
    le = _run(testbed, LowestEnergyRouter, None, scenes)
    assert le.backend_energy_mwh <= orc.backend_energy_mwh <= \
        hmg.backend_energy_mwh + 1e-9


def test_ed_router_close_to_oracle(testbed):
    scenes = sc.full_dataset(40, seed=13)
    orc = _run(testbed, OracleRouter, OracleEstimator(), scenes)
    ed = _run(testbed, GreedyEstimateRouter, EdgeDetectionEstimator(), scenes)
    assert ed.map_pct >= orc.map_pct - 10.0
    assert ed.gateway_energy_mwh > orc.gateway_energy_mwh  # estimation costs


def test_ob_cheap_on_video(testbed):
    video = sc.video_dataset(n_frames=50, seed=3)
    ob = _run(testbed, GreedyEstimateRouter, OutputBasedEstimator(), video)
    ed = _run(testbed, GreedyEstimateRouter, EdgeDetectionEstimator(), video)
    assert ob.gateway_energy_mwh < ed.gateway_energy_mwh
    assert ob.map_pct > 0


def test_sf_estimator_runs(testbed):
    params, table = testbed
    scenes = sc.full_dataset(15, seed=14)
    sf = SSDFrontEndEstimator(params["ssd_v1"], "ssd_v1")
    stats = _run(testbed, GreedyEstimateRouter, sf, scenes)
    assert stats.map_pct > 0
    assert stats.gateway_energy_mwh > 0


def test_delta_zero_matches_hmg_choices(testbed):
    """delta=0 greedy == HMG accuracy-wise (Theorem 3.1 corner)."""
    scenes = sc.full_dataset(30, seed=15)
    hmg = _run(testbed, HighestMAPPerGroupRouter, None, scenes)
    orc0 = _run(testbed, OracleRouter, OracleEstimator(), scenes, delta=0.0)
    assert abs(orc0.map_pct - hmg.map_pct) < 5.0


def test_delta_sweep_monotone_energy(testbed):
    scenes = sc.full_dataset(30, seed=16)
    energies = []
    for delta in (0.0, 10.0, 100.0):
        s = _run(testbed, OracleRouter, OracleEstimator(), scenes,
                 delta=delta)
        energies.append(s.backend_energy_mwh)
    assert energies[0] >= energies[1] >= energies[2]

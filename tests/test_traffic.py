"""repro.traffic: arrivals, SLO sketches, LoadDriver, fleet elasticity.

Everything here runs on the ManualClock — no wall-clock sleeps, no
flusher threads (services are built with ``flusher=False``), so every
episode is bit-reproducible and the suite stays fast.
"""
import numpy as np
import pytest

from _propcheck import given, settings, st

import repro.traffic as tr
from repro.core.energy import mwh_to_joules
from repro.traffic.slo import Completion


# ------------------------------------------------------------- arrivals


def test_poisson_arrivals_deterministic_sorted_and_bounded():
    a = tr.poisson_arrivals(40.0, 5.0, seed=11)
    b = tr.poisson_arrivals(40.0, 5.0, seed=11)
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) >= 0)
    assert a[0] >= 0.0 and a[-1] < 5.0
    assert not np.array_equal(a, tr.poisson_arrivals(40.0, 5.0, seed=12))


def test_all_patterns_deterministic_and_offset_by_t0():
    for pattern in tr.ARRIVAL_PATTERNS:
        a = tr.make_arrivals(pattern, 20.0, 4.0, seed=3)
        b = tr.make_arrivals(pattern, 20.0, 4.0, seed=3)
        assert np.array_equal(a, b), pattern
        shifted = tr.make_arrivals(pattern, 20.0, 4.0, seed=3, t0=100.0)
        assert np.allclose(shifted, a + 100.0), pattern


def test_make_arrivals_rejects_unknown_pattern():
    with pytest.raises(ValueError, match="unknown arrival pattern"):
        tr.make_arrivals("burst", 1.0, 1.0)


def test_degenerate_rates_yield_empty_streams():
    assert len(tr.poisson_arrivals(0.0, 10.0)) == 0
    assert len(tr.poisson_arrivals(5.0, 0.0)) == 0


@settings(max_examples=12, deadline=None)
@given(rate=st.floats(min_value=5.0, max_value=120.0),
       seed=st.integers(min_value=0, max_value=2**16))
def test_poisson_empirical_rate(rate, seed):
    duration = 20.0
    n = len(tr.poisson_arrivals(rate, duration, seed=seed))
    expected = rate * duration
    # Poisson count: mean n, std sqrt(n); 6 sigma keeps flakes impossible
    assert abs(n - expected) < 6.0 * np.sqrt(expected) + 10


@settings(max_examples=8, deadline=None)
@given(base=st.floats(min_value=10.0, max_value=60.0),
       seed=st.integers(min_value=0, max_value=2**16))
def test_diurnal_empirical_rate_over_whole_periods(base, seed):
    # whole periods: the sinusoid integrates out, mean rate = base
    period, duration = 10.0, 40.0
    ts = tr.diurnal_arrivals(base, duration, period_s=period, seed=seed)
    expected = base * duration
    assert abs(len(ts) - expected) < 6.0 * np.sqrt(expected) + 10
    # and the intensity genuinely swings: peak-phase quarters beat
    # trough-phase quarters (amplitude 0.5 -> 3x intensity ratio)
    phase = (ts % period) / period
    peak = np.sum((phase >= 0.0) & (phase < 0.5))    # sin >= 0 half
    trough = np.sum(phase >= 0.5)
    assert peak > trough


def test_flash_crowd_spike_concentrates_mass():
    ts = tr.flash_crowd_arrivals(10.0, 10.0, spike_hz=80.0,
                                 spike_start_s=4.0, spike_len_s=2.0,
                                 seed=5)
    in_spike = np.sum((ts >= 4.0) & (ts < 6.0))
    outside = len(ts) - in_spike
    # 2s at 80/s vs 8s at 10/s: the spike holds ~2/3 of the mass
    assert in_spike > outside


def test_flash_crowd_rejects_spike_below_base():
    with pytest.raises(ValueError, match="below base"):
        tr.flash_crowd_arrivals(10.0, 10.0, spike_hz=5.0)


def test_diurnal_amplitude_validation():
    with pytest.raises(ValueError, match="amplitude"):
        tr.diurnal_arrivals(10.0, 10.0, amplitude=1.5)


def test_manual_clock_semantics():
    clock = tr.ManualClock(5.0)
    assert clock() == 5.0
    clock.advance(1.5)
    assert clock() == 6.5
    clock.advance_to(6.0)          # behind now: clamped, never rewinds
    assert clock() == 6.5
    clock.advance_to(10.0)
    assert clock() == 10.0
    with pytest.raises(ValueError):
        clock.advance(-0.1)


# ------------------------------------------------------------ SLO plane


def test_latency_sketch_relative_error_bound():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=3.0, sigma=1.2, size=20_000)
    sk = tr.LatencySketch(rel_err=0.01)
    for v in vals:
        sk.add(float(v))
    for q in (0.1, 0.5, 0.9, 0.95, 0.99):
        exact = float(np.quantile(vals, q))
        assert abs(sk.quantile(q) - exact) / exact < 0.03, q
    assert np.isclose(sk.mean, vals.mean())


def test_latency_sketch_merge_equals_bulk_add():
    rng = np.random.default_rng(1)
    a, b = rng.exponential(10.0, 500), rng.exponential(40.0, 700)
    ska, skb, skall = (tr.LatencySketch() for _ in range(3))
    for v in a:
        ska.add(float(v))
        skall.add(float(v))
    for v in b:
        skb.add(float(v))
        skall.add(float(v))
    merged = ska.merge(skb)
    assert merged.count == skall.count
    for q in (0.5, 0.95, 0.99):
        assert merged.quantile(q) == skall.quantile(q)


def test_latency_sketch_zero_bucket_and_validation():
    sk = tr.LatencySketch(min_value=1e-3)
    for v in (0.0, 0.0005, 0.001):
        sk.add(v)
    assert sk.quantile(0.5) == 0.0
    with pytest.raises(ValueError):
        sk.add(-1.0)
    with pytest.raises(ValueError):
        sk.add(float("nan"))
    with pytest.raises(ValueError):
        sk.quantile(1.5)
    with pytest.raises(ValueError):
        tr.LatencySketch().merge(tr.LatencySketch(rel_err=0.05))


def _completion(uid, t_arr, t_start, t_done, *, tenant="a", ok=True,
                deadline_ms=None, energy_mwh=0.0, service_ms=None):
    if service_ms is None:
        service_ms = (t_done - t_start) * 1e3
    return Completion(uid=uid, tenant=tenant, t_arrival=t_arr,
                      t_start=t_start, t_done=t_done, service_ms=service_ms,
                      energy_mwh=energy_mwh, deadline_ms=deadline_ms, ok=ok)


def test_completion_latency_split_and_deadline_verdict():
    c = _completion(0, 1.0, 1.2, 1.5, deadline_ms=600.0)
    assert np.isclose(c.queue_wait_ms, 200.0)
    assert np.isclose(c.e2e_ms, 500.0)
    assert c.within_deadline
    assert not _completion(1, 1.0, 1.2, 1.7,
                           deadline_ms=600.0).within_deadline
    assert not _completion(2, 1.0, 1.2, 1.3, ok=False,
                           deadline_ms=600.0).within_deadline
    assert _completion(3, 1.0, 1.2, 9.0).within_deadline  # no deadline


def test_windowed_slo_buckets_by_completion_time():
    slo = tr.WindowedSLO(window_s=1.0)
    slo.record(_completion(0, 0.0, 0.1, 0.5, energy_mwh=2.0))
    slo.record(_completion(1, 0.2, 0.3, 0.9, energy_mwh=2.0))
    slo.record(_completion(2, 0.8, 1.5, 2.5, energy_mwh=5.0,
                           deadline_ms=100.0))
    recs = slo.window_records()
    assert [r["t_start_s"] for r in recs] == [0.0, 2.0]
    assert recs[0]["n"] == 2 and recs[1]["n"] == 1
    assert np.isclose(recs[0]["joules_per_request"],
                      mwh_to_joules(4.0) / 2)
    assert recs[0]["goodput_rps"] == 2.0      # no deadline: served = good
    assert recs[1]["goodput_rps"] == 0.0      # 1700ms e2e vs 100ms deadline
    s = slo.summary()
    assert s["completions"] == 3 and s["failed"] == 0
    assert np.isclose(s["goodput_fraction"], 2 / 3)
    assert s["windows"] == 2


def test_windowed_slo_per_tenant_counts():
    slo = tr.WindowedSLO(window_s=10.0)
    slo.record(_completion(0, 0.0, 0.0, 1.0, tenant="det"))
    slo.record(_completion(1, 0.0, 0.0, 1.0, tenant="llm", ok=False))
    t = slo.window_records()[0]["tenants"]
    assert t["det"] == {"n": 1, "good": 1}
    assert t["llm"] == {"n": 1, "good": 0}


# ------------------------------------------------------------- tenants


def test_detector_tenant_counts_drift_at_shift_frac():
    arr = np.linspace(0.0, 10.0, 400)
    ten = tr.detector_tenant("cam", arr, seed=0, shift_frac=0.5)
    reqs = [ten.make_request(uid, i) for uid, i in enumerate(range(400))]
    first = np.mean([r.true_complexity for r in reqs[:200]])
    second = np.mean([r.true_complexity for r in reqs[200:]])
    # COUNT_PROBS is sparse-heavy; its mirror is crowded-heavy
    assert second > first + 1.0


def test_llm_tenant_prompt_lengths_and_cap():
    arr = np.linspace(0.0, 1.0, 50)
    ten = tr.llm_tenant("llm", arr, seed=0, prompt_cap=48)
    for i in range(50):
        r = ten.make_request(i, i)
        assert r.complexity in (32, 128, 1024, 4096, 40_000)
        assert len(r.payload) == min(r.complexity, 48)


def test_merge_tenants_orders_by_time_and_assigns_unique_uids():
    a = tr.detector_tenant("a", np.array([0.5, 2.0]), seed=0)
    b = tr.llm_tenant("b", np.array([1.0, 1.5]), seed=0)
    merged = tr.merge_tenants([a, b])
    assert [t.tenant for t in merged] == ["a", "b", "b", "a"]
    assert [t.t for t in merged] == [0.5, 1.0, 1.5, 2.0]
    assert [t.request.uid for t in merged] == [0, 1, 2, 3]


def test_merge_tenants_requests_independent_of_merge_order():
    arr = np.linspace(0.0, 2.0, 20)
    mk = lambda: [tr.detector_tenant("a", arr, seed=1),
                  tr.llm_tenant("b", arr + 0.01, seed=2)]
    ab = tr.merge_tenants(mk())
    ba = tr.merge_tenants(list(reversed(mk())))
    # same global timeline -> same per-tenant payloads at each time slot
    by_time_ab = {(t.t, t.tenant): t.request.true_complexity
                  for t in ab if t.tenant == "a"}
    by_time_ba = {(t.t, t.tenant): t.request.true_complexity
                  for t in ba if t.tenant == "a"}
    assert by_time_ab == by_time_ba


# ------------------------------------------------ profile elasticity ops


def _nominal_state():
    from repro.detection.devices import nominal_profile_table
    table = nominal_profile_table()
    return table.as_arrays()


def _decide_all(state, arrays):
    import jax.numpy as jnp
    from repro.core import DEFAULT_GROUP_RULES
    from repro.core.router import decide_state, rules_arrays
    lo, hi, rr = rules_arrays(DEFAULT_GROUP_RULES, arrays.row_of)
    out = []
    for c in range(9):
        g, col, ok = decide_state(state, jnp.int32(c), 5.0, lo, hi, rr)
        out.append((int(g), int(col), bool(ok)))
    return out


def test_add_then_retire_pair_restores_decisions_bit_identically():
    from repro.core import add_pair, retire_pair
    arrays = _nominal_state()
    base = _decide_all(arrays.state, arrays)
    grown, idx = add_pair(arrays.state, map_pct=10.0, time_ms=1e6,
                          energy_mwh=1e6)
    assert idx == len(arrays.pairs)
    assert grown.pair_id.shape[1] == arrays.state.pair_id.shape[1] + 1
    shrunk = retire_pair(grown, idx)
    assert _decide_all(shrunk, arrays) == base
    # the retired column is a full pad: invalid, -1 id, infinite costs
    col = np.asarray(shrunk.valid)[:, -1]
    assert not col.any()
    assert (np.asarray(shrunk.pair_id)[:, -1] == -1).all()
    assert np.isinf(np.asarray(shrunk.time_ms)[:, -1]).all()


def test_add_pair_strictly_better_pair_wins():
    import jax.numpy as jnp
    from repro.core import add_pair
    arrays = _nominal_state()
    grown, idx = add_pair(arrays.state, map_pct=99.0, time_ms=0.01,
                          energy_mwh=1e-9)
    decisions = _decide_all(grown, arrays)
    last_col = grown.pair_id.shape[1] - 1
    assert all(col == last_col for _, col, ok in decisions if ok)
    assert (np.asarray(grown.fails)[:, -1] == 0).all()


def test_add_pair_accepts_per_group_vectors():
    from repro.core import add_pair
    arrays = _nominal_state()
    g = arrays.state.map_pct.shape[0]
    per_group = np.linspace(10.0, 90.0, g).astype(np.float32)
    grown, _ = add_pair(arrays.state, map_pct=per_group, time_ms=1.0,
                        energy_mwh=0.5)
    assert np.allclose(np.asarray(grown.map_pct)[:, -1], per_group)


def test_retire_pair_unknown_index_is_identity():
    from repro.core import retire_pair
    arrays = _nominal_state()
    out = retire_pair(arrays.state, 10_000)
    for a, b in zip(out, arrays.state):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_retire_pair_is_jittable():
    import jax
    from repro.core import retire_pair
    arrays = _nominal_state()
    jitted = jax.jit(retire_pair)(arrays.state, 0)
    eager = retire_pair(arrays.state, 0)
    for a, b in zip(jitted, eager):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------- virtual-time service


def _detection_service(clock, **kw):
    from repro.core import OracleRouter
    from repro.core.policy import DetectionPolicy
    from repro.detection.devices import nominal_profile_table
    from repro.serving.backend import make_backend, null_run
    from repro.serving.service import EcoreService
    table = nominal_profile_table()
    policy = DetectionPolicy(OracleRouter(table, 5.0), table)

    def factory(decision):
        return make_backend("detector", decision.pair[0], decision.pair[1],
                            None, max_batch=4, run_fn=null_run)
    return EcoreService(policy, factory, clock=clock, flusher=False, **kw)


def _req(uid, count=1):
    from repro.core.policy import RouteRequest
    return RouteRequest(uid=uid, payload=np.zeros((8, 8), np.float32),
                        true_complexity=count)


def test_service_next_deadline_and_flush_due_on_manual_clock():
    clock = tr.ManualClock()
    svc = _detection_service(clock, max_wait_ms=50.0)
    try:
        assert svc.next_deadline() is None
        clock.advance_to(1.0)
        fut = svc.submit(_req(0))
        assert np.isclose(svc.next_deadline(), 1.05)
        assert svc.flush_due() == 0          # deadline not reached
        assert not fut.done()
        clock.advance_to(1.05)
        assert svc.flush_due() == 1
        assert fut.done() and fut.result().request.uid == 0
        assert svc.deadline_flushes == 1
        assert svc.next_deadline() is None   # queue drained
    finally:
        svc.close()


def test_service_flusher_false_never_starts_a_thread():
    clock = tr.ManualClock()
    svc = _detection_service(clock, max_wait_ms=10.0)
    try:
        assert svc._flusher is None
        assert svc.flusher_passes == 0
    finally:
        svc.close()


# ------------------------------------------------------ fleet elasticity


def _cluster(clock, pods=2, max_pods=4, **kw):
    from repro.core import OracleRouter
    from repro.core.policy import DetectionPolicy
    from repro.detection.devices import nominal_profile_table
    from repro.serving.backend import make_backend, null_run
    from repro.serving.cluster import EcoreCluster

    def policy_for(i):
        table = nominal_profile_table()
        return DetectionPolicy(OracleRouter(table, 5.0), table)

    def factory(decision):
        return make_backend("detector", decision.pair[0], decision.pair[1],
                            None, max_batch=4, run_fn=null_run)
    return EcoreCluster(policy_for, factory, pods=pods, max_pods=max_pods,
                        clock=clock, flusher=False, retain_results=False,
                        **kw)


def test_cluster_retire_then_add_revives_the_same_pod():
    cl = _cluster(tr.ManualClock(), pods=3, max_pods=3)
    try:
        assert cl.live_pods() == [0, 1, 2]
        assert cl.retire_pod() == 2          # highest-index live pod
        assert cl.live_pods() == [0, 1]
        assert cl.stats()["retired"] == [2]
        assert cl.add_pod() == 2             # revived, not appended
        assert cl.live_pods() == [0, 1, 2]
        assert cl.stats()["retired"] == []
        assert len(cl.pods) == 3
    finally:
        cl.close()


def test_cluster_add_pod_appends_up_to_max_pods():
    cl = _cluster(tr.ManualClock(), pods=2, max_pods=3)
    try:
        assert cl.can_add_pod()
        assert cl.add_pod() == 2
        assert len(cl.pods) == 3
        assert not cl.can_add_pod()
        with pytest.raises(RuntimeError, match="max_pods"):
            cl.add_pod()
    finally:
        cl.close()


def test_cluster_never_retires_the_last_live_pod():
    cl = _cluster(tr.ManualClock(), pods=2, max_pods=2)
    try:
        cl.retire_pod()
        with pytest.raises(ValueError, match="last live pod"):
            cl.retire_pod()
        with pytest.raises(ValueError, match="not live"):
            cl.retire_pod(1)                 # already retired
    finally:
        cl.close()


def test_cluster_retired_pod_receives_no_new_work():
    cl = _cluster(tr.ManualClock(), pods=2, max_pods=2)
    try:
        cl.retire_pod(1)
        futs = [cl.submit(_req(uid)) for uid in range(8)]
        cl.drain()
        assert all(f.result().request.uid == u
                   for u, f in enumerate(futs))
        assert all(cl.owner_of(u) == 0 for u in range(8))
        assert cl.stats()["shard_counts"][1] == 0
    finally:
        cl.close()


def test_cluster_max_pods_validation():
    with pytest.raises(ValueError, match="max_pods"):
        _cluster(tr.ManualClock(), pods=4, max_pods=2)


def test_autoscaler_watermark_validation():
    from repro.serving.cluster import Autoscaler
    cl = _cluster(tr.ManualClock(), pods=2, max_pods=4)
    try:
        with pytest.raises(ValueError, match="hysteresis"):
            Autoscaler(cl, tr.ManualClock(), high_backlog_per_pod=2.0,
                       low_backlog_per_pod=2.0)
        with pytest.raises(ValueError, match="min_pods"):
            Autoscaler(cl, tr.ManualClock(), min_pods=0)
    finally:
        cl.close()


def test_autoscaler_scales_up_on_backlog_and_down_when_idle():
    from repro.serving.cluster import Autoscaler
    clock = tr.ManualClock()
    cl = _cluster(clock, pods=2, max_pods=4)
    auto = Autoscaler(cl, clock, min_pods=2, max_pods=4,
                      high_backlog_per_pod=5.0, low_backlog_per_pod=1.0,
                      cooldown_s=1.0)
    try:
        assert auto.tick(4) is None          # inside the band
        assert auto.tick(20) == "add"        # 10/pod >= 5
        assert auto.tick(20) is None         # cooldown gates the next one
        clock.advance(1.0)
        assert auto.tick(20) == "add"        # 6.7/pod, now at max_pods=4
        clock.advance(1.0)
        assert auto.tick(100) is None        # can't exceed max
        clock.advance(1.0)
        assert auto.tick(0) == "retire"
        clock.advance(1.0)
        assert auto.tick(0) == "retire"
        clock.advance(1.0)
        assert auto.tick(0) is None          # floor at min_pods=2
        assert cl.live_pods() == [0, 1]
        assert [e["action"] for e in auto.events] == [
            "add", "add", "retire", "retire"]
        assert all("t_s" in e and "backlog" in e for e in auto.events)
    finally:
        cl.close()


# ----------------------------------------------------------- LoadDriver


def _run_episode(rate, duration, *, autoscale=False, seed=3,
                 deadline_ms=80.0, pattern="poisson"):
    from repro.serving.cluster import Autoscaler
    clock = tr.ManualClock()
    cl = _cluster(clock, pods=2, max_pods=4, max_wait_ms=20.0)
    auto = Autoscaler(cl, clock, min_pods=2, max_pods=4,
                      high_backlog_per_pod=8.0, low_backlog_per_pod=1.0,
                      cooldown_s=0.5) if autoscale else None
    arrivals = tr.make_arrivals(pattern, rate, duration, seed=seed)
    work = tr.merge_tenants([tr.detector_tenant(
        "cam", arrivals, seed=1, deadline_ms=deadline_ms)])
    driver = tr.LoadDriver(cl, clock, autoscaler=auto, window_s=1.0)
    try:
        done = driver.run(work)
    finally:
        cl.close()
    return done, driver, auto


def test_load_driver_completes_every_request_deterministically():
    a, drv_a, _ = _run_episode(60.0, 3.0)
    b, drv_b, _ = _run_episode(60.0, 3.0)
    assert len(a) == len(b) > 50
    assert a == b                            # full Completion equality
    assert drv_a.slo.summary() == drv_b.slo.summary()
    assert {c.uid for c in a} == set(range(len(a)))
    assert drv_a.backlog() == 0              # episode fully drained


def test_load_driver_latency_split_is_consistent():
    done, _, _ = _run_episode(60.0, 2.0)
    for c in done:
        assert c.t_arrival <= c.t_start <= c.t_done
        assert np.isclose(c.e2e_ms, c.queue_wait_ms
                          + (c.t_done - c.t_start) * 1e3)
        assert c.ok and c.pair is not None


def test_load_driver_underload_meets_deadline_overload_grows_queue():
    light, drv_l, _ = _run_episode(40.0, 2.0, deadline_ms=120.0)
    s_light = drv_l.slo.summary()
    assert s_light["goodput_fraction"] == 1.0
    # open loop: 30x the rate has nowhere to shed -> queue waits explode
    heavy, drv_h, _ = _run_episode(1200.0, 2.0, deadline_ms=120.0)
    s_heavy = drv_h.slo.summary()
    assert s_heavy["queue_wait_p99_ms"] > 10 * s_light["queue_wait_p99_ms"]
    assert s_heavy["goodput_fraction"] < 0.9
    assert s_heavy["p99_ms"] > s_light["p99_ms"]


def test_load_driver_autoscaled_flash_beats_fixed_fleet():
    kw = dict(duration=6.0, deadline_ms=100.0, pattern="flash")
    _, drv_fixed, _ = _run_episode(700.0, **kw)
    _, drv_auto, auto = _run_episode(700.0, autoscale=True, **kw)
    fixed, scaled = drv_fixed.slo.summary(), drv_auto.slo.summary()
    assert any(e["action"] == "add" for e in auto.events)
    assert scaled["p99_ms"] < fixed["p99_ms"]
    assert scaled["goodput_fraction"] > fixed["goodput_fraction"]


def test_load_driver_fires_deadline_flushes_at_exact_virtual_times():
    done, drv, _ = _run_episode(30.0, 2.0)
    # sub-max_batch traffic: every flush is deadline-triggered, so queue
    # waits concentrate AT the 20ms max_wait (modulo same-batch sharing)
    waits = [c.queue_wait_ms for c in done]
    assert max(waits) <= 20.0 + 1e-6
    s = drv.slo.summary()
    assert s["queue_wait_p99_ms"] <= 21.0


def test_load_driver_records_multi_tenant_slos():
    clock = tr.ManualClock()
    cl = _cluster(clock, pods=2, max_pods=2, max_wait_ms=10.0)
    det = tr.detector_tenant(
        "cam", tr.poisson_arrivals(40.0, 2.0, seed=1), seed=1,
        deadline_ms=100.0)
    work = tr.merge_tenants([det])
    driver = tr.LoadDriver(cl, clock, window_s=0.5)
    try:
        done = driver.run(work)
    finally:
        cl.close()
    recs = driver.slo.window_records()
    assert len(recs) >= 3
    assert all(r["tenants"]["cam"]["n"] > 0 for r in recs)
    assert sum(r["n"] for r in recs) == len(done)
    assert all(r["joules_per_request"] > 0 for r in recs)
